"""Class-based incremental ``SharedLink`` vs the materialized reference.

The K-class water-filling accounting (PR 8) must be invisible:

  - per-flow rates are **bit-equal** to the materialized fallback's
    (both run the identical class-sequence arithmetic) over random
    ``(cap, prio)`` mixes and random add/advance/remove interleavings;
  - flows complete in the same order at the same times;
  - an engine riding the class path equals the same engine forced onto
    the legacy materialized path (``incremental=False``) — wall, costs,
    per-iteration times, invocations;
  - same-seed runs are bit-identical for heterogeneous-fleet, serving,
    and co-scheduled train+serve configs;
  - the post-join drain cascade engages where the regime exists (small
    compute spread, aggregate-bound drains) and changes nothing.

Property tests use hypothesis when available and fall back to a
fixed-seed random sweep otherwise (the container may not ship it).
"""
import numpy as np
import pytest

from repro.serverless import (WORKLOADS, EventEngine, FleetSpec, ObjectStore,
                              ParamStore, ServingJob)
from repro.serverless.events import ContentionDomain, _Transfer
from repro.serverless.stores import SharedLink
from repro.serving import ServePolicy

try:
    from hypothesis import given, settings, strategies as st

    def sweep(test):
        return settings(max_examples=30, deadline=None)(
            given(st.integers(min_value=0, max_value=2**31 - 1))(test))
except ImportError:                                   # fallback shim
    def sweep(test):
        def run():
            for seed in np.random.RandomState(1234).randint(
                    0, 2**31 - 1, size=30):
                test(int(seed))
        run.__name__ = test.__name__
        run.__doc__ = test.__doc__
        return run


CAPS = [0.05, 0.1, 0.4, None]      # None -> link per-stream default
PRIOS = [1.0, 2.0, 4.0]


def _mk_links():
    kw = dict(aggregate_gbps=1.0, per_stream_gbps=0.8, latency_s=0.0)
    return (SharedLink("a", **kw), SharedLink("b", incremental=False, **kw))


def _mk_tr(rng, link):
    return _Transfer(link, float(rng.uniform(1e6, 5e8)), 0.0, lambda: None,
                     False, cap_gbps=CAPS[rng.randint(len(CAPS))],
                     prio=PRIOS[rng.randint(len(PRIOS))],
                     weight=int(rng.randint(1, 4)))


@sweep
def test_rates_bit_equal_to_materialized_reference(seed):
    """Random add/advance/remove interleavings over random (cap, prio,
    weight) mixes: the class path's per-flow rates are bit-equal to the
    materialized fallback's at every step."""
    rng = np.random.RandomState(seed)
    inc, ref = _mk_links()
    live = []
    now = 0.0
    for _ in range(40):
        op = rng.rand()
        if op < 0.55 or not live:
            pair = []
            for link in (inc, ref):
                tr = _mk_tr(rng, link)
                # identical flow on both links (fids differ; sizes match)
                if pair:
                    tr.remaining_gb = pair[0].remaining_gb
                    tr.total_gb = pair[0].total_gb
                    tr.cap_gbps = pair[0].cap_gbps
                    tr.prio = pair[0].prio
                    tr.weight = pair[0].weight
                link.add_flow(tr, now)
                pair.append(tr)
            live.append(pair)
        elif op < 0.8:
            now += float(rng.uniform(0.0, 0.5))
            inc.progress(now)
            ref.progress(now)
        else:
            a, b = live.pop(rng.randint(len(live)))
            inc.remove_flow(a, now)
            ref.remove_flow(b, now)
        ri = inc.rates()
        rr = ref.rates()
        for (a, b) in live:
            assert ri[a.fid] == rr[b.fid]      # bit-equal, not approx
        assert sum(ri.values()) == pytest.approx(sum(rr.values()))


@sweep
def test_completion_order_matches_reference(seed):
    """Draining both links to empty yields the same completion order at
    the same times (1e-12 rel: the two paths accumulate the served
    integral in a different association order)."""
    rng = np.random.RandomState(seed)
    inc, ref = _mk_links()
    pairs = []
    now = 0.0
    for _ in range(12):
        pair = []
        for link in (inc, ref):
            tr = _mk_tr(rng, link)
            if pair:
                tr.remaining_gb = pair[0].remaining_gb
                tr.cap_gbps = pair[0].cap_gbps
                tr.prio = pair[0].prio
                tr.weight = pair[0].weight
            link.add_flow(tr, now)
            pair.append(tr)
        pairs.append(pair)
        now += float(rng.uniform(0.0, 0.2))
        inc.progress(now)
        ref.progress(now)
    ref_of = {a.fid: b.fid for a, b in pairs}
    guard = 0
    while inc.flows:
        dt_i = inc.next_completion_dt()
        dt_r = ref.next_completion_dt()
        assert dt_i == pytest.approx(dt_r, rel=1e-12, abs=1e-15)
        now += dt_i
        inc.progress(now)
        ref.progress(now)
        done_i = inc.take_drained(eps_gb=1e-9)
        done_r = ref.take_drained(eps_gb=1e-9)
        # a same-instant batch is a set: class mode yields per-class heap
        # order, the reference yields insertion order
        assert (sorted(ref_of[t.fid] for t in done_i)
                == sorted(t.fid for t in done_r))
        guard += 1
        assert guard < 100
    assert not ref.flows


def _hetero_engine(incremental, *, sigma=0.3, seed=9):
    fleet = FleetSpec.mixed([(5, 2048, "standard"), (3, 3072, "large")])
    eng = EventEngine(WORKLOADS["resnet18"], "hier", 8, 2048, 4096,
                      ParamStore(), ObjectStore(), samples=3 * 4096,
                      fleet=fleet, straggler_sigma=sigma, seed=seed)
    if not incremental:
        for link in eng.links.values():
            link.incremental = False       # force the legacy/materialized path
    return eng


def test_hetero_engine_class_path_equals_materialized_path():
    """A mixed-cap sigma>0 run on the class-based links equals the same
    run forced onto the legacy materialized path."""
    a = _hetero_engine(True).run()
    b = _hetero_engine(False).run()
    assert a.iters_done == b.iters_done
    assert a.invocations == b.invocations
    assert a.wall_s == pytest.approx(b.wall_s, rel=1e-9)
    assert a.lambda_usd == pytest.approx(b.lambda_usd, rel=1e-9)
    assert a.store_usd == pytest.approx(b.store_usd, rel=1e-9)
    assert a.iter_times == pytest.approx(b.iter_times, rel=1e-9)
    assert len(a.trace) == len(b.trace)


def test_hetero_same_seed_bit_identity():
    a = _hetero_engine(True).run()
    b = _hetero_engine(True).run()
    assert a.wall_s == b.wall_s
    assert a.lambda_usd == b.lambda_usd
    assert a.store_usd == b.store_usd
    assert a.iter_times == b.iter_times
    assert a.trace == b.trace
    assert a.sim_events == b.sim_events


def _serving_job(ps=None, dom=None, prio=1.0):
    pol = ServePolicy(4, 0.1, 2048)
    arr = np.sort(np.random.RandomState(3).uniform(0.0, 20.0, size=300))
    return ServingJob(pol, arr, 2e9, ps or ParamStore(), ObjectStore(),
                      domain=dom, model_bytes=100e6, code_bytes=10e6,
                      cold_start_s=0.5, keep_warm_s=10.0, max_instances=8,
                      refresh_every_s=2.0, link_priority=prio)


def test_serving_same_seed_bit_identity():
    a = _serving_job().run()
    b = _serving_job().run()
    assert (a.wall_s, a.lambda_usd, a.store_usd, a.p50_s, a.p99_s) == \
           (b.wall_s, b.lambda_usd, b.store_usd, b.p50_s, b.p99_s)
    assert a.sim_events == b.sim_events


def test_multi_job_same_seed_bit_identity():
    """Train + serve on one ParamStore in one domain: two (cap, prio)
    classes on the shared param link; the whole co-run is repeatable
    bit-for-bit."""
    def corun():
        dom = ContentionDomain()
        ps = ParamStore()
        eng = EventEngine(WORKLOADS["resnet18"], "ps", 8, 2048, 4096,
                          ps, ObjectStore(), samples=2 * 4096, seed=4,
                          domain=dom, trace_enabled=False)
        job = _serving_job(ps, dom, prio=4.0)
        dom.run()
        return eng.result(), job.result()
    ta, sa = corun()
    tb, sb = corun()
    assert (ta.wall_s, ta.lambda_usd, ta.store_usd) == \
           (tb.wall_s, tb.lambda_usd, tb.store_usd)
    assert (sa.wall_s, sa.p99_s, sa.cost_usd) == (sb.wall_s, sb.p99_s,
                                                  sb.cost_usd)


def test_drain_cascade_fires_and_is_exact():
    """Small compute spread + aggregate-bound drains: after the last
    member joins, the remaining drains cascade inline. The cascade must
    actually engage, and the run must equal the per-worker reference."""
    from repro.serverless.events import ContentionDomain as CD
    orig = CD._cascade
    count = [0]

    def wrapped(self, link, c, win):
        count[0] += 1
        return orig(self, link, c, win)

    CD._cascade = wrapped
    try:
        def run(coalesce):
            return EventEngine(WORKLOADS["bert-medium"], "hier", 32, 2048,
                               256, ParamStore(), ObjectStore(),
                               samples=3 * 256, straggler_sigma=0.01,
                               seed=7, record_trace=False,
                               coalesce=coalesce).run()
        a = run(None)
        assert count[0] > 0            # the cascade regime was exercised
        b = run(False)
    finally:
        CD._cascade = orig
    assert a.iters_done == b.iters_done
    assert a.wall_s == pytest.approx(b.wall_s, rel=1e-9)
    assert a.lambda_usd == pytest.approx(b.lambda_usd, rel=1e-9)
    assert a.store_usd == pytest.approx(b.store_usd, rel=1e-9)
    assert a.iter_times == pytest.approx(b.iter_times, rel=1e-9)
