"""Substrate tests: checkpointing, data pipeline, sharding rules, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointMeta, DiskCheckpointer, StoreCheckpointer
from repro.configs import ARCHS
from repro.data import DataConfig, IteratorState, OnlineStream, ShardedLoader, TokenDataset
from repro.distributed.sharding import param_specs
from repro.models import registry
from repro.optim import AdamW
from repro.serverless import ObjectStore


# -- checkpoint --------------------------------------------------------------


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_disk_checkpoint_roundtrip(tmp_path):
    ck = DiskCheckpointer(str(tmp_path))
    t = _tree()
    ck.save("m", t, CheckpointMeta(step=3, epoch=1, index=42))
    back, meta = ck.restore("m", t)
    assert meta.step == 3 and meta.index == 42
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_store_checkpoint_roundtrip_and_timing():
    store = ObjectStore()
    ck = StoreCheckpointer(store)
    t = _tree()
    t_up = ck.save("m", t, CheckpointMeta(step=1))
    back, meta, t_down = ck.restore("m", t)
    assert t_up > 0 and t_down > 0
    assert meta.step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert store.stats.puts >= 2  # payload + meta were billed


# -- data --------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, dataset_tokens=16 * 64)
    a = ShardedLoader(TokenDataset(cfg))
    b = ShardedLoader(TokenDataset(cfg))
    for _ in range(3):
        np.testing.assert_array_equal(a.next_batch(8)["tokens"],
                                      b.next_batch(8)["tokens"])
    # resume from a checkpointed iterator state
    state = IteratorState(epoch=a.state.epoch, index=a.state.index)
    resumed = ShardedLoader(TokenDataset(cfg), state)
    np.testing.assert_array_equal(a.next_batch(8)["tokens"],
                                  resumed.next_batch(8)["tokens"])


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=100, seq_len=64)
    toks = ShardedLoader(TokenDataset(cfg)).next_batch(32)["tokens"]
    # consecutive tokens follow cur+shift mod V most of the time
    diffs = (toks[:, 1:] - toks[:, :-1]) % cfg.vocab_size
    vals, counts = np.unique(diffs, return_counts=True)
    assert counts.max() / diffs.size > 0.5


def test_online_stream_rate_varies():
    s = OnlineStream(base_rate=10.0, seed=0)
    lo = s.arrivals(0.75 * 86_400, 600)        # trough
    hi = s.arrivals(0.25 * 86_400, 600)        # peak
    assert hi > lo


# -- sharding rules ----------------------------------------------------------


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_param_specs_divisible(arch_id):
    """Every sharded dim divides the 16-way model axis, for every arch."""
    cfg = ARCHS[arch_id]
    shapes = jax.eval_shape(lambda k: registry.init(k, cfg),
                            jax.random.key(0))
    specs = param_specs(shapes, model_size=16, fsdp_axis="data",
                        fsdp_divisor=16)
    flat_shapes = jax.tree_util.tree_leaves_with_path(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_model_sharded = 0
    for (path, shp), spec in zip(flat_shapes, flat_specs):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            assert shp.shape[dim] % 16 == 0, (path, shp.shape, spec)
            if ax == "model":
                n_model_sharded += 1
    assert n_model_sharded > 0, "no tensor parallelism found"


def test_moe_expert_fallback():
    """qwen2-moe: 60 experts don't divide 16 -> per-expert FFN TP instead."""
    cfg = ARCHS["qwen2-moe-a2.7b"]
    shapes = jax.eval_shape(lambda k: registry.init(k, cfg),
                            jax.random.key(0))
    specs = param_specs(shapes, model_size=16)
    wi_spec = specs["blocks"]["moe"]["experts"]["wi"]
    assert wi_spec == P(None, None, None, "model")
    # arctic's 128 experts DO divide 16 -> expert parallel
    cfg2 = ARCHS["arctic-480b"]
    shapes2 = jax.eval_shape(lambda k: registry.init(k, cfg2),
                             jax.random.key(0))
    specs2 = param_specs(shapes2, model_size=16)
    assert specs2["blocks"]["moe"]["experts"]["wi"] == P(None, "model")


# -- optimizer ---------------------------------------------------------------


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip():
    opt = AdamW(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    g = {"x": jnp.array([1e6, 0.0, 0.0])}
    p2, _ = opt.update(g, state, params)
    assert np.all(np.isfinite(np.asarray(p2["x"])))
    assert abs(float(p2["x"][0])) < 1.0
