"""End-to-end behaviour tests for the SMLT system."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced, reduced_batch
from repro.core import ConfigSpace, EpochPlan, Goal, TaskScheduler
from repro.models import registry
from repro.optim import apply_sgd
from repro.serverless import (WORKLOADS, LocalWorkerPool, ObjectStore,
                              ParamStore, ServerlessPlatform)


def test_semantic_smlt_trains_real_model():
    """A real (reduced olmo) model trained by n logical serverless workers
    synchronizing through the param store: loss decreases AND the training
    path is exactly the single-worker full-batch path."""
    cfg = reduced(ARCHS["olmo-1b"]).replace(n_layers=1, d_model=64)
    batch = reduced_batch(cfg, batch=8, seq=16)
    params0 = registry.init(jax.random.key(0), cfg)

    grad_fn = jax.jit(lambda p, b: jax.grad(
        lambda q: registry.loss_fn(q, cfg, b))(p))
    loss_fn = jax.jit(lambda p, b: registry.loss_fn(p, cfg, b))

    def run(n_workers, steps=5, lr=0.1):
        pool = LocalWorkerPool(grad_fn, n_workers, ParamStore())
        p = params0
        losses = []
        for _ in range(steps):
            losses.append(float(loss_fn(p, batch)))
            g = pool.step(p, batch)
            p = apply_sgd(p, g, lr)
        return losses

    l4 = run(4)
    l1 = run(1)
    assert l4[-1] < l4[0], "loss must decrease"
    np.testing.assert_allclose(l4, l1, rtol=1e-4)


def test_dynamic_batching_throughput_recovers():
    """Fig. 12 shape: throughput dips are corrected after re-optimization
    when batch size quadruples mid-run."""
    plat = ServerlessPlatform()
    sched = TaskScheduler(plat, ObjectStore(), ParamStore(),
                          space=ConfigSpace(max_workers=150), seed=0)
    w = WORKLOADS["resnet50"]
    batches = [256] * 2 + [2048] * 3
    res = sched.run([EpochPlan(b, w, samples=40_000) for b in batches],
                    Goal("min_time"))
    eps = [e for e in res.events if e.kind == "epoch"]
    assert len(eps) == 5
    # workers were re-chosen when batch grew
    assert len({(e.workers, e.memory_mb) for e in eps}) >= 2
    # larger batch -> higher samples/s after adaptation
    assert eps[-1].throughput > eps[0].throughput


def test_end_to_end_cost_accounting_consistent():
    """Ledger components (lambda + stores + profiling) are all accounted."""
    plat = ServerlessPlatform()
    ps, os_ = ParamStore(), ObjectStore()
    sched = TaskScheduler(plat, os_, ps, seed=1,
                          space=ConfigSpace(max_workers=64))
    res = sched.run([EpochPlan(512, WORKLOADS["resnet18"], samples=30_000)],
                    Goal("min_cost"))
    assert res.total_cost == pytest.approx(res.cost_usd + res.profile_usd)
    assert res.cost_usd > 0
    assert ps.alive_seconds > 0              # param store billed during sync
    assert plat.ledger.gb_seconds > 0        # lambda GB-s accrued


def test_scheduler_is_deterministic():
    def run():
        sched = TaskScheduler(ServerlessPlatform(seed=7), ObjectStore(),
                              ParamStore(), seed=7,
                              space=ConfigSpace(max_workers=80))
        return sched.run([EpochPlan(1024, WORKLOADS["bert-small"],
                                    samples=20_000)] * 2, Goal("min_time"))

    a, b = run(), run()
    assert a.wall_s == b.wall_s and a.total_cost == b.total_cost
    assert [c.workers for c in a.config_history] == \
           [c.workers for c in b.config_history]
