"""Workflow layer: task DAGs, budget/deadline allocation, successive-halving
HPO, and the orchestrator that co-schedules tasks on one shared fleet
(paper Sections 1 and 3.1 — the "overarching view" over a continuous
workflow of design and training tasks)."""
import pytest

from repro.core import Config, ConfigSpace, Goal
from repro.serverless import (WORKLOADS, ObjectStore, ParamStore,
                              ServerlessPlatform)
from repro.workflow import (BudgetAllocator, HPOSweep, SuccessiveHalving,
                            TaskSpec, WorkflowDAG, WorkflowOrchestrator,
                            expand_hpo, sweep_final_tasks)

W = WORKLOADS["resnet18"]


def chain_dag(epochs=(2, 1, 1), samples=(4096, 2048, 1024)):
    return WorkflowDAG([
        TaskSpec("train", W, epochs=epochs[0], batch_size=512,
                 samples=samples[0]),
        TaskSpec("finetune", W, epochs=epochs[1], batch_size=512,
                 samples=samples[1], deps=("train",), kind="finetune",
                 warm_start_from="train"),
        TaskSpec("eval", W, epochs=epochs[2], batch_size=512,
                 samples=samples[2], deps=("finetune",), kind="eval"),
    ])


def orchestrate(dag, goal, *, engine="analytic", sweeps=(), seed=0,
                max_workers=32, max_memory=4096):
    plat = ServerlessPlatform(seed=seed)
    orch = WorkflowOrchestrator(
        dag, goal, plat, ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=max_workers, max_memory=max_memory),
        engine=engine, sweeps=sweeps, seed=seed)
    return orch, orch.run()


# -- DAG ---------------------------------------------------------------------

def test_dag_validation():
    with pytest.raises(ValueError, match="duplicate"):
        WorkflowDAG([TaskSpec("a", W), TaskSpec("a", W)])
    with pytest.raises(ValueError, match="unknown dependency"):
        WorkflowDAG([TaskSpec("a", W, deps=("ghost",))])
    with pytest.raises(ValueError, match="itself"):
        TaskSpec("a", W, deps=("a",))
    with pytest.raises(ValueError, match="cycle"):
        WorkflowDAG([TaskSpec("a", W, deps=("b",)),
                     TaskSpec("b", W, deps=("a",))])
    with pytest.raises(ValueError, match="kind"):
        TaskSpec("a", W, kind="banana")


def test_dag_order_ready_descendants():
    dag = WorkflowDAG([
        TaskSpec("c", W, deps=("a", "b")),
        TaskSpec("a", W),
        TaskSpec("b", W, deps=("a",)),
        TaskSpec("d", W, deps=("c",)),
    ])
    assert dag.order == ["a", "b", "c", "d"]
    assert [t.name for t in dag.ready(done=set())] == ["a"]
    assert [t.name for t in dag.ready(done={"a"})] == ["b"]
    assert [t.name for t in dag.ready(done={"a", "b"})] == ["c"]
    assert dag.descendants("a") == {"b", "c", "d"}
    assert dag.descendants("d") == set()


def test_dag_tails_and_critical_path():
    dag = WorkflowDAG([
        TaskSpec("root", W),
        TaskSpec("long", W, deps=("root",)),
        TaskSpec("short", W, deps=("root",)),
        TaskSpec("sink", W, deps=("long", "short")),
    ])
    walls = {"root": 10.0, "long": 100.0, "short": 5.0, "sink": 20.0}
    tails = dag.tails(walls)
    assert tails["sink"] == 0.0
    assert tails["long"] == 20.0
    assert tails["root"] == pytest.approx(120.0)
    length, path = dag.critical_path(walls)
    assert length == pytest.approx(130.0)
    assert path == ["root", "long", "sink"]


# -- allocator ---------------------------------------------------------------

def test_allocator_grants_priorities_and_windows():
    dag = WorkflowDAG([
        TaskSpec("hi", W, epochs=1, batch_size=512, samples=8192, priority=4),
        TaskSpec("lo", W, epochs=1, batch_size=512, samples=8192, priority=1),
    ])
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=4.0)
    alloc = BudgetAllocator(dag, goal, ParamStore(), ObjectStore(),
                            space=ConfigSpace(max_workers=32))
    grants, drops = alloc.allocate(now_s=0.0, spent_usd=0.0, running={},
                                   finished=set(), dropped=set(),
                                   ready=["hi", "lo"])
    assert not drops
    # identical forecasts: the split is pure priority (4:1), up to the
    # critical-path boost landing on one of the two equal chains
    assert grants["hi"].budget_usd > grants["lo"].budget_usd
    total = sum(g.budget_usd for g in grants.values())
    assert total <= goal.budget_usd * alloc.safety + 1e-9
    # every grant respects the global deadline
    assert all(g.deadline_s <= goal.deadline_s for g in grants.values())
    # dollars -> workers: a bigger grant never narrows the window
    lo_w = alloc.workers_for_budget("hi", grants["lo"].budget_usd)
    hi_w = alloc.workers_for_budget("hi", grants["hi"].budget_usd)
    assert hi_w[1] >= lo_w[1]
    assert hi_w[0] >= lo_w[0] >= 1


def test_allocator_reallocates_unspent_budget():
    """Unspent grants flow back: with one task finished *under* its
    grant, the follower's grant exceeds what it would have been had the
    full grant been spent."""
    dag = WorkflowDAG([
        TaskSpec("first", W, epochs=1, batch_size=512, samples=8192),
        TaskSpec("second", W, epochs=1, batch_size=512, samples=8192,
                 deps=("first",)),
    ])
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=2.0)
    alloc = BudgetAllocator(dag, goal, ParamStore(), ObjectStore(),
                            space=ConfigSpace(max_workers=32))
    g0, _ = alloc.allocate(now_s=0.0, spent_usd=0.0, running={},
                           finished=set(), dropped=set(), ready=["first"])
    cheap, _ = alloc.allocate(now_s=100.0, spent_usd=0.1 * g0["first"].budget_usd,
                              running={}, finished={"first"},
                              dropped=set(), ready=["second"])
    dear, _ = alloc.allocate(now_s=100.0, spent_usd=g0["first"].budget_usd,
                             running={}, finished={"first"},
                             dropped=set(), ready=["second"])
    assert cheap["second"].budget_usd > dear["second"].budget_usd


def test_allocator_drops_by_priority_under_deadline_pressure():
    dag = WorkflowDAG([
        TaskSpec("must", W, epochs=1, batch_size=512, samples=8192,
                 priority=5),
        TaskSpec("nice", W, epochs=1, batch_size=512, samples=8192,
                 priority=1, droppable=True, deps=("must",)),
        TaskSpec("nice-child", W, epochs=1, batch_size=512, samples=8192,
                 deps=("nice",), droppable=True, priority=3),
    ])
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=4.0)
    alloc = BudgetAllocator(dag, goal, ParamStore(), ObjectStore(),
                            space=ConfigSpace(max_workers=32))
    # pretend most of the deadline is gone: only the must-task's chain fits
    chain = alloc.forecasts["must"].wall_s + alloc.forecasts["nice"].wall_s
    grants, drops = alloc.allocate(
        now_s=goal.deadline_s - chain * 1.01, spent_usd=0.0, running={},
        finished=set(), dropped=set(), ready=["must"])
    # the lowest-priority droppable goes first, dragging its dependent
    assert "nice" in drops and "nice-child" in drops
    assert "must" in grants


# -- tuner -------------------------------------------------------------------

def test_expand_hpo_shape_and_deps():
    sweep = HPOSweep("hpo", W, n_trials=8, rungs=2, eta=2, seed=1)
    specs = expand_hpo(sweep)
    names = [s.name for s in specs]
    assert len([n for n in names if ":r0:" in n]) == 8
    assert len([n for n in names if ":r1:" in n]) == 4
    r0 = tuple(n for n in names if ":r0:" in n)
    for s in specs:
        if s.rung == 1:
            assert s.deps == r0           # selection barrier
        else:
            assert s.deps == ()
    assert sweep_final_tasks(sweep) == tuple(n for n in names if ":r1:" in n)
    with pytest.raises(ValueError):
        HPOSweep("bad", W, n_trials=2, rungs=3, eta=2)


def test_successive_halving_selection_and_warm_start():
    sweep = HPOSweep("hpo", W, n_trials=4, rungs=2, eta=2, seed=7)
    tuner = SuccessiveHalving(sweep)
    specs = {s.name: s for s in expand_hpo(sweep)}
    cfgs = {}
    for i in range(4):
        spec = specs[f"hpo:r0:t{i}"]
        assert tuner.assign(spec) == i
        cfgs[i] = Config(workers=4 + i, memory_mb=1024)
        tuner.report(spec, epochs_done=1, config=cfgs[i])
    ranked = tuner.survivors_of(0)
    assert len(ranked) == 4
    assert tuner.scores[ranked[0]] <= tuner.scores[ranked[-1]]
    s0 = specs["hpo:r1:s0"]
    assert tuner.assign(s0) == ranked[0]          # best trial takes slot 0
    assert tuner.warm_config(s0) == cfgs[ranked[0]]
    # more epochs always improves the synthetic curve
    for trial in range(4):
        assert tuner.loss(trial, 2) < tuner.loss(trial, 1)
    best, loss = tuner.best()
    assert best == ranked[0] and loss == tuner.scores[best]


# -- orchestrator ------------------------------------------------------------

def test_workflow_analytic_chain():
    dag = chain_dag()
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=10.0)
    orch, res = orchestrate(dag, goal, engine="analytic")
    assert set(res.tasks) == {"train", "finetune", "eval"}
    assert not res.dropped
    # chain executes in order on the workflow clock
    assert res.finish_s["train"] <= res.start_s["finetune"]
    assert res.finish_s["finetune"] <= res.start_s["eval"]
    assert res.wall_s == pytest.approx(res.finish_s["eval"])
    assert res.wall_s <= goal.deadline_s
    # one shared bill, fully attributed per task
    assert res.ledger_usd <= goal.budget_usd
    assert res.cost_usd == pytest.approx(res.ledger_usd, rel=1e-6)
    ledger = orch.platform.ledger
    assert set(ledger.job_usd) == {"train", "finetune", "eval"}
    assert sum(ledger.job_usd.values()) == pytest.approx(res.cost_usd,
                                                         rel=1e-6)


def test_workflow_event_tasks_overlap_on_shared_domain():
    dag = WorkflowDAG([
        TaskSpec("a", W, epochs=1, batch_size=512, samples=4096),
        TaskSpec("b", W, epochs=1, batch_size=512, samples=4096),
        TaskSpec("join", W, epochs=1, batch_size=512, samples=2048,
                 deps=("a", "b"), kind="eval"),
    ])
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=10.0)
    orch, res = orchestrate(dag, goal, engine="event", max_workers=16)
    # a and b ran concurrently: the makespan beats the serial schedule
    serial = sum(r.wall_s for r in res.tasks.values())
    assert res.wall_s < serial
    assert res.start_s["a"] == res.start_s["b"] == 0.0
    assert res.start_s["join"] == pytest.approx(
        max(res.finish_s["a"], res.finish_s["b"]))
    # keep-alive billing stays honest across staggered engine results:
    # the single param store is billed exactly the cross-task union
    assert orch.param_store.alive_seconds == pytest.approx(
        orch.domain.sync_union_s, rel=1e-9)


def test_workflow_seed_determinism():
    def trace():
        dag = chain_dag(epochs=(1, 1, 1))
        goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=10.0)
        _, res = orchestrate(dag, goal, engine="event", max_workers=16)
        return res.trace
    assert trace() == trace()       # bit-identical workflow event log


def hpo_workflow(budget=3.0):
    sweep = HPOSweep("hpo", W, n_trials=8, rungs=2, eta=2,
                     epochs_per_rung=1, batch_size=512, samples=16384,
                     seed=3)
    specs = expand_hpo(sweep)
    specs.append(TaskSpec("finetune", W, epochs=1, batch_size=512,
                          samples=16384, deps=sweep_final_tasks(sweep),
                          kind="finetune", warm_start_from="hpo",
                          priority=3))
    dag = WorkflowDAG(specs)
    goal = Goal("deadline_budget", deadline_s=3600.0, budget_usd=budget)
    return dag, goal, sweep


def test_workflow_hpo_end_to_end():
    """Acceptance: an 8-trial, 2-rung successive-halving sweep plus a
    dependent fine-tune completes under one global Goal — ledger within
    budget, makespan within deadline — and the budget reclaimed from
    early-stopped losers demonstrably re-allocates: the winning trial's
    final rung is granted more dollars and runs with more workers than
    its first rung."""
    dag, goal, sweep = hpo_workflow()
    orch, res = orchestrate(dag, goal, engine="event", sweeps=[sweep])
    # every rung-0 trial and the fine-tune actually trained
    for name in dag.order:
        assert res.tasks[name].epochs_done >= 1, name
    assert not res.dropped
    assert res.ledger_usd <= goal.budget_usd
    assert res.wall_s <= goal.deadline_s
    # the losers were early-stopped: only n/eta survivor slots exist, and
    # the pool they free flows to the winner's final rung
    winner, loss = res.winners["hpo"]
    r0 = f"hpo:r0:t{winner}"
    r1 = next(n for n, t in res.assignments.items()
              if t == winner and ":r1:" in n)
    assert res.allocations[r1].budget_usd > res.allocations[r0].budget_usd
    assert res.config_of(r1).workers > res.config_of(r0).workers
    # the surviving rung warm-started from its rung-0 deployment
    assert winner in orch.tuners["hpo"].configs
    # the fine-tune warm-starts from the sweep winner and runs last
    assert res.start_s["finetune"] == pytest.approx(
        max(res.finish_s[n] for n in sweep_final_tasks(sweep)))


def test_workflow_hpo_bit_identical_trace():
    def run():
        dag, goal, sweep = hpo_workflow()
        _, res = orchestrate(dag, goal, engine="event", sweeps=[sweep])
        return res
    a, b = run(), run()
    assert a.trace == b.trace
    assert a.wall_s == b.wall_s and a.cost_usd == b.cost_usd


def test_workflow_tight_budget_truncates_not_overspends():
    """With a budget too small for every trial, tasks are truncated by
    their budget stops (zero-epoch trials are legal) — but the ledger
    never exceeds the global budget."""
    dag, goal, sweep = hpo_workflow(budget=1.2)
    orch, res = orchestrate(dag, goal, engine="event", sweeps=[sweep])
    assert res.ledger_usd <= goal.budget_usd
    assert set(res.tasks) | set(res.dropped) == set(dag.order)


# -- deploy / online_update: the continuous train->serve loop ----------------

def test_deploy_task_validation():
    from repro.serverless import ArrivalSpec, ServingTask
    from repro.serving import ServePolicy
    sv = ServingTask(policy=ServePolicy(8, 0.2, 2048),
                     arrivals=ArrivalSpec(base_rps=5.0), duration_s=60.0,
                     flops_per_request=2e9)
    with pytest.raises(ValueError, match="needs a ServingTask"):
        TaskSpec("d", W, kind="deploy")
    with pytest.raises(ValueError, match="only valid on"):
        TaskSpec("t", W, kind="train", serving=sv)
    spec = TaskSpec("d", W, kind="deploy", serving=sv)
    with pytest.raises(ValueError, match="ServingJob"):
        spec.plans()


def test_workflow_deploy_and_online_update():
    """train -> eval -> deploy -> online_update as one goal-bounded DAG:
    the deploy task runs as a ServingJob on the shared domain, its
    serving detail lands in WorkflowResult.serving, and its cost is
    attributed on the one shared ledger."""
    from repro.serverless import (ArrivalSpec, ObjectStore, ParamStore,
                                  ServerlessPlatform, ServingTask)
    from repro.serving import ServePolicy
    sv = ServingTask(policy=ServePolicy(8, 0.2, 2048),
                     arrivals=ArrivalSpec(base_rps=20.0,
                                          bursts_per_hour=6.0),
                     duration_s=90.0, flops_per_request=2e9,
                     model_bytes=50e6, code_bytes=5e6, slo_s=1.0,
                     cold_start_s=0.8, keep_warm_s=30.0, max_instances=8)
    dag = WorkflowDAG([
        TaskSpec("train", W, epochs=1, batch_size=512, samples=4096),
        TaskSpec("eval", W, epochs=1, batch_size=512, samples=1024,
                 deps=("train",), kind="eval"),
        TaskSpec("deploy", W, deps=("eval",), kind="deploy", serving=sv),
        TaskSpec("update", W, epochs=1, batch_size=512, samples=2048,
                 deps=("deploy",), kind="online_update",
                 warm_start_from="train"),
    ])
    plat = ServerlessPlatform(seed=0)
    orch = WorkflowOrchestrator(
        dag, Goal("deadline_budget", deadline_s=4000.0, budget_usd=50.0),
        plat, ObjectStore(), ParamStore(),
        space=ConfigSpace(max_workers=16), engine="event", seed=0)
    res = orch.run()
    assert set(res.tasks) == {"train", "eval", "deploy", "update"}
    srv = res.serving["deploy"]
    assert srv.requests > 0 and srv.batches > 0
    # the deploy task flows through normal DAG bookkeeping
    assert res.finish_s["eval"] <= res.start_s["deploy"]
    assert res.finish_s["deploy"] <= res.start_s["update"] + 1e-9
    assert res.tasks["deploy"].wall_s == pytest.approx(srv.wall_s)
    # one ledger, per-job attribution (ServingJob self-attributes)
    assert plat.ledger.job_usd["deploy"] == pytest.approx(srv.cost_usd)
    assert res.cost_usd == pytest.approx(
        sum(r.total_cost for r in res.tasks.values()))
    # the serve trace lines made it into the deterministic log
    assert any(line.split(" ", 1)[1].startswith("serve deploy")
               for line in res.trace)
    assert any(line.split(" ", 1)[1].startswith("served deploy")
               for line in res.trace)
